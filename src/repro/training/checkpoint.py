"""Checkpointing: flat-path .npz snapshots of the TrainState pytree.

Thin delegation over the repo's one checkpoint codec
(``repro.runtime.snapshot.save_pytree`` / ``load_pytree`` — host-gathered
leaves keyed by tree path, atomic writes, no external deps); this module
only keeps the training-loop conventions: ``ckpt_<step>.npz`` naming and
the ``(state, step)`` restore contract.
"""

from __future__ import annotations

import os
import re

import numpy as np

from repro.runtime.snapshot import load_pytree, save_pytree


def save(directory: str, state, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    return save_pytree(path, state, meta={"step": int(step)})


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(directory: str, state_like, step: int | None = None):
    """Restore into the structure of ``state_like``. Returns (state, step)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    state, meta = load_pytree(path, state_like)
    if meta is None:
        # pre-codec file: the step travelled in a reserved array key (the
        # leaf paths are unchanged, so the state itself loaded fine)
        with np.load(path) as data:
            if "__step__" not in data:
                raise ValueError(f"{path} has neither checkpoint meta nor "
                                 f"a legacy __step__ key")
            return state, int(data["__step__"])
    return state, int(meta["step"])
