"""llama-3.2-vision-11b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder + projector is a STUB (DESIGN.md §4): ``input_specs``
provides precomputed patch embeddings (B, n_image_tokens, d_model). The
language decoder — 40 layers with a cross-attention layer every 5th — is
implemented in full."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", arch_type="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
    cross_attn_every=5, n_image_tokens=1600, mlp="swiglu",
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", arch_type="vlm", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=2, d_ff=768, vocab=512,
        cross_attn_every=2, n_image_tokens=16, mlp="swiglu", dtype="float32",
        source=CONFIG.source,
    )
