"""Self-healing runtime: probes, ledger, rollback policy, chaos injection.

Four groups:

  1. probes — the jitted all-finite probe and the objective-regression
     monitor (unit level: finite/NaN/inf trees, short histories, missing
     keys, regression firing and its diagnostic).
  2. ledger — typed ``LedgerEvent`` keeps the PR-5 dict-style access
     (``ev["kind"]`` reads the attribute, ``ev["resumed_from"]`` falls
     back to detail), ``ledger_counts`` summaries.
  3. policy — ``HealthGuard`` validation and hooks, ``WallClockMonitor``
     cold/baseline/calm/reset semantics.
  4. chaos — NaN injection through ``engine.solve(health=...)`` across
     the {dense_jnp, sparse_jnp, sparse_bucketed_jnp} backends: the run
     must roll back to the latest valid snapshot, back eta off, and
     re-converge into the fault-free objective envelope; exhausted
     retries raise ``HealthError`` or degrade to ``solve_serial``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import make_classification
from repro.engine import make_grid_data, solve, solve_serial
from repro.engine.data import DSOState
from repro.runtime import (HealthError, HealthGuard, LedgerEvent,
                           NaNInjector, SnapshotStore, WallClockMonitor,
                           all_finite, ledger_counts, objective_regression)


def _prob(m=64, d=48, density=0.15, seed=0):
    return make_classification(m=m, d=d, density=density, loss="hinge",
                               lam=1e-3, seed=seed)


def _state(p=2, db=3, mb=4):
    z = jnp.zeros
    return DSOState(w_grid=z((p, db)), gw_grid=z((p, db)),
                    alpha=z((p, mb)), ga=z((p, mb)), epoch=jnp.int32(0))


# ------------------------------------------------------------------ probes --


def test_all_finite_probe():
    assert all_finite(_state())
    assert all_finite({"a": jnp.ones(3), "b": [np.zeros(2)]})
    assert all_finite({})                      # vacuously healthy
    st = _state()
    assert not all_finite(st._replace(w_grid=st.w_grid.at[0, 1].set(jnp.nan)))
    assert not all_finite(st._replace(alpha=st.alpha.at[1, 0].set(jnp.inf)))
    assert not all_finite({"x": jnp.array([1.0, -jnp.inf])})


def test_objective_regression_monitor():
    hist = [{"epoch": 1, "primal": 1.0}, {"epoch": 2, "primal": 0.5}]
    assert objective_regression(hist) is None
    assert objective_regression(hist[:1]) is None          # needs >= 2
    assert objective_regression([{"epoch": 1}] * 3) is None  # key missing
    diag = objective_regression(hist + [{"epoch": 3, "primal": 5.0}])
    assert diag is not None and "regression" in diag and "0.5" in diag
    diag = objective_regression(hist + [{"epoch": 3, "primal": np.nan}])
    assert diag is not None and "not finite" in diag
    # the slack absorbs noise around a tiny converged objective
    tiny = [{"primal": 1e-5}, {"primal": 2e-5}, {"primal": 9e-4}]
    assert objective_regression(tiny, ratio=2.0, slack=1e-3) is None
    assert objective_regression(tiny, ratio=2.0, slack=0.0) is not None


# ------------------------------------------------------------------ ledger --


def test_ledger_event_dict_compat():
    ev = LedgerEvent(kind="health", epoch=4, action="rollback",
                     epochs_lost=2, retry=1,
                     detail=dict(resumed_from=2, eta0=0.25))
    assert ev["kind"] == "health" and ev["epochs_lost"] == 2
    assert ev["resumed_from"] == 2 and ev["eta0"] == 0.25   # detail fallback
    assert ev.get("worker") is None and ev.get("retry") == 1
    with pytest.raises(KeyError):
        ev["nope"]
    d = ev.to_dict()
    assert d["kind"] == "health" and d["resumed_from"] == 2
    # "detail" itself always resolves to the dict, not an attribute lookup
    assert ev["detail"] == dict(resumed_from=2, eta0=0.25)


def test_ledger_counts():
    ledger = [LedgerEvent(kind="crash"), LedgerEvent(kind="crash"),
              LedgerEvent(kind="health", action="rollback")]
    assert ledger_counts(ledger) == {"crash": 2, "health": 1}
    assert ledger_counts([]) == {}


# ------------------------------------------------------------------ policy --


def test_health_guard_validation_and_hooks():
    with pytest.raises(ValueError, match="eta_decay"):
        HealthGuard(eta_decay=0.0)
    with pytest.raises(ValueError, match="on_exhausted"):
        HealthGuard(on_exhausted="panic")
    g = HealthGuard()
    st = _state()
    assert g.check_state(st) is None
    assert g.check_state(
        st._replace(ga=st.ga.at[0, 0].set(jnp.nan))) == "nonfinite state"
    assert g.inject(st, 3) is st               # no injector: identity
    g.note(kind="health", epoch=2, action="rollback", failure="x")
    assert len(g.ledger) == 1 and g.ledger[0]["failure"] == "x"


def test_nan_injector_fires_once_per_epoch():
    inj = NaNInjector({2: ("w", 1), 4: ("alpha", 0)})
    st = _state()
    assert inj.inject(st, 1) is st             # not planned
    poisoned = inj.inject(st, 2)
    assert not bool(jnp.isfinite(poisoned.w_grid[1]).all())
    assert inj.inject(st, 2) is st             # fired already (rollback-safe)
    poisoned = inj.inject(st, 4)
    assert not bool(jnp.isfinite(poisoned.alpha[0]).all())
    with pytest.raises(ValueError, match="'w' | 'alpha'"):
        NaNInjector({1: ("gw", 0)}).inject(st, 1)


def test_wall_clock_monitor_semantics():
    with pytest.raises(ValueError, match="factor"):
        WallClockMonitor(factor=1.0)
    mon = WallClockMonitor(factor=1.8, patience=1, beta=0.5)
    assert not mon.observe(1.0, cold=True)     # cold: never recorded
    assert mon.baseline is None
    assert not mon.observe(1.0)                # sets baseline
    assert not mon.observe(1.1)                # healthy
    assert mon.observe(9.0)                    # ewma 5.05 > 1.8 -> fires
    mon.calm()                                 # post-replan: baseline kept
    assert mon.baseline == 1.0 and mon.streak == 0
    assert mon.observe(9.0)                    # still slow: escalates
    mon.reset()                                # post-reshard: full restart
    assert mon.baseline is None
    assert not mon.observe(9.0)                # new baseline, no false fire


def test_wall_clock_monitor_patience():
    mon = WallClockMonitor(factor=1.5, patience=2)
    mon.observe(1.0)
    assert not mon.observe(10.0)               # hot streak 1 of 2
    assert mon.observe(10.0)                   # hot streak 2 -> fires


# ------------------------------------------------------------------- chaos --

NAN_MATRIX = [("dense_jnp", "w"), ("dense_jnp", "alpha"),
              ("sparse_jnp", "w"), ("sparse_bucketed_jnp", "w")]


@pytest.mark.parametrize("backend,leaf", NAN_MATRIX)
def test_solve_nan_rollback_reconverges(backend, leaf, tmp_path):
    """A NaN poisoned into the live state mid-run must be caught by the
    finite probe at the next chunk boundary, rolled back to the latest
    valid snapshot with eta backed off, and still re-converge into the
    fault-free objective envelope."""
    prob = _prob()
    ref = solve(prob, backend=backend, p=4, epochs=10, eta0=0.5,
                eval_every=2, seed=7)
    store = SnapshotStore(str(tmp_path))
    guard = HealthGuard(eta_decay=0.7, injector=NaNInjector({4: (leaf, 1)}))
    res = solve(prob, backend=backend, p=4, epochs=10, eta0=0.5,
                eval_every=2, seed=7, checkpoint_every=2, store=store,
                health=guard)
    assert np.isfinite(np.asarray(res.w)).all()
    events = [ev for ev in guard.ledger if ev["kind"] == "health"]
    assert len(events) == 1
    ev = events[0]
    assert ev["action"] == "rollback" and ev["failure"] == "nonfinite state"
    assert ev["resumed_from"] == 4 and ev["epochs_lost"] == 2
    assert ev["eta0"] == pytest.approx(0.5 * 0.7)
    # the poisoned iterate never reached disk: every snapshot verifies
    for ep in store.epochs():
        assert store.verify(ep) == "verified"
    # eta backoff changes the post-rollback trajectory; the objective must
    # still land in the fault-free envelope
    assert abs(res.history[-1]["primal"]
               - ref.history[-1]["primal"]) < 0.05
    # the backoff parameters ride in every snapshot config
    cfg = store.load().config
    assert cfg["eta_decay"] == 0.7 and cfg["max_retries"] == 3


def test_solve_health_exhausted_raises(tmp_path):
    """Zero retry budget: the first failed probe must raise HealthError
    naming the failure and the backed-off step size."""
    prob = _prob()
    guard = HealthGuard(max_retries=0, injector=NaNInjector({0: ("w", 0)}))
    with pytest.raises(HealthError, match="nonfinite state"):
        solve(prob, backend="dense_jnp", p=4, epochs=4, eta0=0.5, seed=7,
              checkpoint_every=2, store=SnapshotStore(str(tmp_path)),
              health=guard)


def test_solve_health_degrades_to_serial(tmp_path):
    """on_exhausted='serial': a Problem source falls back to the
    paper-exact solve_serial safe mode instead of raising."""
    prob = _prob(m=32, d=24)
    guard = HealthGuard(max_retries=0, on_exhausted="serial",
                        injector=NaNInjector({0: ("w", 0)}))
    res = solve(prob, backend="dense_jnp", p=4, epochs=4, eta0=0.5, seed=7,
                eval_every=2, checkpoint_every=2,
                store=SnapshotStore(str(tmp_path)), health=guard)
    assert np.isfinite(np.asarray(res.w)).all()
    assert any(ev["action"] == "degrade_serial" for ev in guard.ledger)
    ref = solve_serial(prob, epochs=4, eta0=0.5, seed=7, eval_every=2)
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(ref.w))


def test_solve_health_serial_needs_problem_source(tmp_path):
    """Pre-built grid data cannot rebuild the pointwise reference — the
    'serial' degradation must refuse with a diagnostic saying so."""
    prob = _prob(m=32, d=24)
    data = make_grid_data(prob, 4)
    guard = HealthGuard(max_retries=0, on_exhausted="serial",
                        injector=NaNInjector({0: ("w", 0)}))
    with pytest.raises(HealthError, match="Problem source"):
        solve(data, backend="dense_jnp", epochs=4, eta0=0.5, seed=7,
              loss_name="hinge", reg_name="l2", lam=prob.lam, m=prob.m,
              d=prob.d, checkpoint_every=2,
              store=SnapshotStore(str(tmp_path)), health=guard)
