"""Table 1 / Sec. 2 correctness: conjugates, saddle objective, duality gap."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional: without it the property tests collect as SKIPPED
from _hypothesis_compat import given, settings, st

from repro.core.losses import LOSSES, get_loss
from repro.core.saddle import (argmin_w, dual_objective, duality_gap,
                               make_problem, primal_objective,
                               saddle_objective)
from repro.data.synthetic import make_classification

jax.config.update("jax_enable_x64", False)


def _num_neg_conj(loss, alpha, y, ugrid):
    """-l*(-a) = min_u [ a*u + l(u) ] evaluated on a dense u-grid."""
    vals = alpha * ugrid + np.asarray(loss.value(jnp.asarray(ugrid), y))
    return vals.min()


@pytest.mark.parametrize("loss_name", ["hinge", "logistic", "square"])
@pytest.mark.parametrize("y", [1.0, -1.0])
def test_conjugate_matches_numeric_min(loss_name, y):
    loss = get_loss(loss_name)
    ugrid = np.linspace(-30, 30, 200001)
    # sample alphas strictly inside the conjugate domain
    for b in [0.05, 0.3, 0.5, 0.7, 0.95]:
        alpha = y * b if loss_name != "square" else (2 * b - 1) * 3.0
        got = float(loss.neg_conjugate(jnp.float32(alpha), jnp.float32(y)))
        want = _num_neg_conj(loss, alpha, jnp.float32(y), ugrid)
        assert np.isclose(got, want, atol=2e-3), (loss_name, y, b, got, want)


@pytest.mark.parametrize("loss_name", ["hinge", "logistic", "square"])
@pytest.mark.parametrize("y", [1.0, -1.0])
def test_dual_grad_matches_autodiff(loss_name, y):
    loss = get_loss(loss_name)
    # d/da [ l*(-a) ] = -d/da [ neg_conjugate(a) ]
    f = lambda a: -loss.neg_conjugate(a, jnp.float32(y))
    for b in [0.1, 0.4, 0.6, 0.9]:
        alpha = jnp.float32(y * b if loss_name != "square" else (2 * b - 1) * 2)
        got = float(loss.dual_grad(alpha, jnp.float32(y)))
        want = float(jax.grad(f)(alpha))
        assert np.isclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("loss_name", ["logistic", "square"])
def test_primal_equals_max_over_alpha(loss_name):
    """max_alpha f(w, alpha) = P(w): attained at alpha* = -l'(<w,x>)."""
    prob = make_classification(m=50, d=20, density=0.3, loss=loss_name,
                               lam=1e-2, seed=3)
    loss = get_loss(loss_name)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.3, prob.d).astype(np.float32))
    u = prob.X @ w
    alpha_star = -loss.grad(u, prob.y)
    f_at_star = saddle_objective(prob, w, alpha_star)
    p = primal_objective(prob, w)
    assert np.isclose(float(f_at_star), float(p), rtol=1e-4, atol=1e-5)
    # and it is a maximum: perturbations decrease f
    for _ in range(5):
        pert = jnp.asarray(rng.normal(0, 0.01, prob.m).astype(np.float32))
        a2 = loss.project_alpha(alpha_star + pert, prob.y)
        assert float(saddle_objective(prob, w, a2)) <= float(p) + 1e-5


def test_dual_equals_min_over_w():
    """D(alpha) = min_w f(w, alpha): attained at the closed-form argmin."""
    prob = make_classification(m=60, d=25, density=0.3, loss="hinge",
                               lam=1e-2, seed=4)
    rng = np.random.default_rng(1)
    alpha = prob.y * jnp.asarray(rng.random(prob.m).astype(np.float32))
    wmin = argmin_w(prob, alpha)
    f_at_min = saddle_objective(prob, wmin, alpha)
    dd = dual_objective(prob, alpha)
    assert np.isclose(float(f_at_min), float(dd), rtol=1e-4, atol=1e-6)
    for _ in range(5):
        pert = jnp.asarray(rng.normal(0, 0.01, prob.d).astype(np.float32))
        assert float(saddle_objective(prob, wmin + pert, alpha)) >= float(dd) - 1e-6


@given(seed=st.integers(0, 10_000), lam=st.floats(1e-5, 1e-1),
       loss=st.sampled_from(["hinge", "logistic", "square"]))
@settings(max_examples=25, deadline=None)
def test_gap_nonnegative_property(seed, lam, loss):
    """Weak duality: gap(w, alpha) >= 0 for any feasible pair."""
    prob = make_classification(m=40, d=15, density=0.4, loss=loss, lam=lam,
                               seed=seed % 50)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, prob.d).astype(np.float32))
    alpha_raw = jnp.asarray(rng.normal(0, 1, prob.m).astype(np.float32))
    alpha = prob.loss.project_alpha(alpha_raw, prob.y)
    g = float(duality_gap(prob, w, alpha))
    assert g >= -1e-4


def test_f_decomposition_eq6():
    """Eq. (6): f(w,a) equals the sum of f_ij over nonzeros."""
    prob = make_classification(m=30, d=12, density=0.5, loss="hinge",
                               lam=1e-2, seed=7)
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 0.5, prob.d).astype(np.float32))
    alpha = prob.loss.project_alpha(
        jnp.asarray(rng.normal(0, 1, prob.m).astype(np.float32)), prob.y)
    X = np.asarray(prob.X)
    ii, jj = np.nonzero(X)
    total = 0.0
    for i, j in zip(ii, jj):
        f_ij = (prob.lam * float(prob.reg.value(w[j])) / float(prob.col_nnz[j])
                + float(prob.loss.neg_conjugate(alpha[i], prob.y[i]))
                / (prob.m * float(prob.row_nnz[i]))
                - float(alpha[i]) * float(w[j]) * X[i, j] / prob.m)
        total += f_ij
    assert np.isclose(total, float(saddle_objective(prob, w, alpha)),
                      rtol=1e-3, atol=1e-4)
