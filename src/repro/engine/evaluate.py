"""Pluggable evaluation hooks for the epoch driver.

A hook is ``hook(t, w, alpha) -> dict`` called once per evaluation chunk
with the gathered (unpadded) iterates; the driver appends its dicts to the
``SolveResult`` history.  Two families:

  ``problem_eval_hook``   — dense ``Problem`` objectives (primal, duality
                            gap, optionally the saddle value);
                            ``pd_gap_eval_hook`` is the variant reporting
                            the primal-dual gap P(w) - D(alpha) itself.
  ``make_csr_primal_eval``— out-of-core: P(w) through a jitted, CHUNKED
                            CSR matvec that never densifies and never
                            round-trips to host numpy.  The CSR stream
                            (indices / row ids / values) moves to device
                            once, reshaped into fixed-size nnz chunks; a
                            ``lax.scan`` gathers w per chunk and
                            scatter-adds into the (m+1,)-slot accumulator
                            (slot m swallows the padding), so the
                            temporary footprint is O(chunk), not O(nnz).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import get_loss
from repro.core.regularizers import get_regularizer
from repro.core.saddle import (dual_objective, duality_gap,
                               primal_objective, saddle_objective)

#: default nnz chunk of the out-of-core evaluation scan (float32+int32
#: working set ~12 MB — comfortably VMEM/L2-resident on any backend)
DEFAULT_CHUNK_NNZ = 1 << 20


def problem_eval_hook(prob, *, saddle: bool = True):
    """History hook computing the dense ``Problem`` objectives."""

    def hook(t, w, alpha):
        h = dict(epoch=t,
                 primal=float(primal_objective(prob, w)),
                 gap=float(duality_gap(prob, w, alpha)))
        if saddle:
            h["saddle"] = float(saddle_objective(prob, w, alpha))
        return h

    return hook


def pd_gap_eval_hook(prob):
    """History hook reporting the primal-dual gap P(w) - D(alpha).

    The gap is the paper's actual convergence certificate (Section 2: the
    saddle formulation sandwiches the optimum between the two
    objectives), so it is the quantity worth watching per epoch; with
    ``solve(..., obs=rec)`` every entry also lands as ``eval.primal`` /
    ``eval.dual`` / ``eval.pd_gap`` gauges in the run-event log.
    """

    def hook(t, w, alpha):
        p = float(primal_objective(prob, w))
        d = float(dual_objective(prob, alpha))
        return dict(epoch=t, primal=p, dual=d, pd_gap=p - d)

    return hook


def make_csr_primal_eval(csr, y, lam: float, loss_name: str = "hinge",
                         reg_name: str = "l2",
                         chunk_nnz: int = DEFAULT_CHUNK_NNZ):
    """Device-side P(w) evaluation hook for an ingested ``CSRMatrix``.

    Returns ``hook(t, w, alpha) -> {"epoch", "primal"}``; the underlying
    jitted scalar function is exposed as ``hook.primal(w)`` for callers
    that only want the objective.  Build once per dataset — the CSR
    arrays are staged to device here, not per call.
    """
    nnz = max(csr.nnz, 1)
    chunk = max(1, min(int(chunk_nnz), nnz))
    n_chunks = -(-nnz // chunk)
    pad = n_chunks * chunk - csr.nnz
    # padding slots: val 0 scattered into the extra slot m -> exact no-op
    idx = np.concatenate([csr.indices,
                          np.zeros(pad, np.int32)]).reshape(n_chunks, chunk)
    rid = np.concatenate([csr.row_ids(),
                          np.full(pad, csr.m, np.int64)]) \
        .astype(np.int32).reshape(n_chunks, chunk)
    val = np.concatenate([csr.values,
                          np.zeros(pad, np.float32)]).reshape(n_chunks, chunk)
    idx_d, rid_d, val_d = jnp.asarray(idx), jnp.asarray(rid), jnp.asarray(val)
    y_d = jnp.asarray(np.asarray(y, np.float32))
    m = csr.m
    loss = get_loss(loss_name)
    reg = get_regularizer(reg_name)
    lam_f = jnp.float32(lam)

    @jax.jit
    def primal(w):
        w = jnp.asarray(w, jnp.float32)

        def body(acc, args):
            i, r, v = args
            return acc.at[r].add(v * jnp.take(w, i)), None

        acc, _ = jax.lax.scan(body, jnp.zeros(m + 1, jnp.float32),
                              (idx_d, rid_d, val_d))
        u = acc[:m]                      # slot m swallowed the padding
        return lam_f * jnp.sum(reg.value(w)) + jnp.mean(loss.value(u, y_d))

    def hook(t, w, alpha):
        return dict(epoch=t, primal=float(primal(w)))

    hook.primal = primal
    return hook
