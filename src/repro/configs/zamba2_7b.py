"""zamba2-7b — Mamba2 backbone + shared attention block [arXiv:2411.15242].

81 Mamba2 layers with one *shared* attention+MLP block applied every 6
layers (the Zamba2 shared-transformer pattern, simplified: a single shared
block without per-invocation LoRA)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", arch_type="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, shared_attn_every=6,
    mlp="swiglu",
    source="arXiv:2411.15242",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", arch_type="hybrid", n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=512,
        ssm_state=16, ssm_expand=2, ssm_head_dim=32, shared_attn_every=2,
        mlp="swiglu", dtype="float32",
        source=CONFIG.source,
    )
