"""Checkpointing: flat-path .npz snapshots of the TrainState pytree.

No external deps (orbax absent in this environment): leaves are gathered to
host, keyed by their tree path, and restored by path. Works for any pytree
of arrays; step metadata travels in a reserved key.
"""

from __future__ import annotations

import os
import re

import jax
import numpy as np

_STEP_KEY = "__step__"
_SEP = "|"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return f"d:{k.key}"
    if isinstance(k, jax.tree_util.SequenceKey):
        return f"i:{k.idx}"
    if isinstance(k, jax.tree_util.GetAttrKey):
        return f"a:{k.name}"
    return f"x:{k}"


def save(directory: str, state, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(state)
    flat[_STEP_KEY] = np.asarray(step)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(directory: str, state_like, step: int | None = None):
    """Restore into the structure of ``state_like``. Returns (state, step)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(
        state_like)
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = _SEP.join(_key_str(k) for k in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(jax.numpy.asarray(arr, leaf.dtype))
    return treedef.unflatten(new_leaves), int(data[_STEP_KEY])
