"""Training loop: loss, pjit train_step builder, host driver."""

from __future__ import annotations

import functools
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training import optimizer as opt

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState


def lm_loss(params, batch, cfg: ModelConfig, *, remat: bool = True,
            q_chunk: int = 2048, unroll: bool = False):
    """Next-token cross entropy (+ MoE router aux loss)."""
    logits, aux = M.forward(params, batch, cfg, remat=remat, q_chunk=q_chunk,
                            unroll=unroll)
    targets = batch["targets"]
    tgt = targets[:, 1:]
    if cfg.loss_impl == "lse":
        # §Perf: logsumexp-based NLL — no materialized (N, V) log-probs and
        # no full-tensor pad-mask pass (pad columns enter the lse; their
        # contribution is trained down exactly like other never-target ids).
        lg = logits[:, :-1]
        lse = jax.nn.logsumexp(lg.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(lg, tgt[..., None],
                                     axis=-1)[..., 0].astype(jnp.float32)
        nll = lse - picked
    else:
        if cfg.padded_vocab != cfg.vocab:  # mask vocab-padding logits out
            pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32),
                                  axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is not None:
        mask = mask[:, 1:]
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    else:
        loss = nll.mean()
    total = loss + cfg.router_aux_weight * aux["aux_loss"]
    return total, {"loss": loss, "aux_loss": aux["aux_loss"]}


def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig, *,
                    remat: bool = True, q_chunk: int = 2048,
                    unroll: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch):
        grad_fn = jax.value_and_grad(
            functools.partial(lm_loss, cfg=cfg, remat=remat,
                              q_chunk=q_chunk, unroll=unroll), has_aux=True)
        (total, metrics), grads = grad_fn(state.params, batch)
        params, opt_state, om = opt.apply(ocfg, state.params, grads,
                                          state.opt)
        metrics = dict(metrics, total=total, **om)
        return TrainState(params, opt_state), metrics

    return train_step


def make_sharded_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig, mesh,
                            batch_shapes: dict, *, remat: bool = True,
                            q_chunk: int = 2048):
    """jit the train step with explicit in/out shardings for ``mesh``.

    ``batch_shapes``: dict of ShapeDtypeStruct for the batch pytree.
    Returns (jitted_fn, state_shardings, batch_shardings).
    """
    pspecs = M.param_specs(cfg)
    p_sh = shd.param_shardings(mesh, pspecs)
    state_sh = TrainState(
        params=p_sh,
        opt=opt.OptState(mu=p_sh, nu=p_sh,
                         step=NamedSharding(mesh, P())),
    )
    d_specs = shd.data_specs(mesh, batch_shapes)
    d_sh = {k: NamedSharding(mesh, s) for k, s in d_specs.items()}
    step_fn = make_train_step(cfg, ocfg, remat=remat, q_chunk=q_chunk)
    jit_fn = jax.jit(step_fn, in_shardings=(state_sh, d_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    return jit_fn, state_sh, d_sh


def init_state(key, cfg: ModelConfig) -> TrainState:
    params = M.init_params(key, cfg)
    return TrainState(params=params, opt=opt.init(params))


def train_loop(cfg: ModelConfig, ocfg: opt.AdamWConfig, data_iter, steps: int,
               *, seed: int = 0, log_every: int = 10, remat: bool = True,
               checkpoint_dir: str | None = None, checkpoint_every: int = 0):
    """Single-host training driver (CPU-scale models)."""
    from repro.training import checkpoint as ckpt
    state = init_state(jax.random.PRNGKey(seed), cfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg, remat=remat))
    history = []
    t0 = time.time()
    for step in range(steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall"] = time.time() - t0
            history.append(m)
        if checkpoint_dir and checkpoint_every and \
                (step + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, state, step + 1)
    return state, history
