"""AdaGrad step sizes (Duchi et al.), as used by the paper (App. B).

Diagonal accumulator G += g^2; effective step = eta0 / sqrt(G + eps).
The primal accumulator travels with its w-shard through the DSO ring; the
dual accumulator stays resident with alpha.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-8


def init(shape, dtype=jnp.float32) -> Array:
    return jnp.zeros(shape, dtype)


def step(g: Array, acc: Array, eta0: float) -> tuple[Array, Array]:
    """Returns (scaled update, new accumulator)."""
    acc = acc + g * g
    return eta0 * g * jax.lax.rsqrt(acc + _EPS), acc
