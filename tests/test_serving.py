"""Serving runtime: engine behaviour, batched requests, cache semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.model import init_params
from repro.serving.engine import DecodeEngine, Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("granite-3-8b")
    params = init_params(KEY, cfg)
    return DecodeEngine(cfg, params, batch=4, seq_len=128)


def test_engine_completes_requests(engine):
    reqs = [Request(prompt=[1, 2, 3], max_new=5),
            Request(prompt=[4, 5], max_new=3)]
    done = engine.run(reqs)
    assert len(done[0].out) == 5 and len(done[1].out) == 3
    assert all(0 <= t < engine.cfg.vocab for r in done for t in r.out)


def test_greedy_is_deterministic():
    cfg = get_smoke_config("qwen1.5-4b")
    params = init_params(KEY, cfg)
    outs = []
    for _ in range(2):
        eng = DecodeEngine(cfg, params, batch=2, seq_len=64)
        r = eng.run([Request(prompt=[7, 8, 9], max_new=6)])[0]
        outs.append(tuple(r.out))
    assert outs[0] == outs[1]


def test_ssm_engine_runs():
    cfg = get_smoke_config("mamba2-370m")
    params = init_params(KEY, cfg)
    eng = DecodeEngine(cfg, params, batch=2, seq_len=64)
    r = eng.run([Request(prompt=[3, 1, 4, 1, 5], max_new=4)])[0]
    assert len(r.out) == 4
