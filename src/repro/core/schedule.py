"""Block-cyclic schedule of Algorithm 1.

At inner iteration r (0-indexed), processor q owns the w-block
``sigma(q, r, p) = (q + r) mod p`` — the 0-indexed form of the paper's
``sigma_r(q) = ((q + r - 2) mod p) + 1``. After each inner iteration the
w-blocks move one step around the ring: processor q receives the block held
by processor (q + 1) mod p.
"""

from __future__ import annotations

import numpy as np


def sigma(q: int, r: int, p: int) -> int:
    """0-indexed owner schedule: block owned by processor q at inner iter r."""
    return (q + r) % p


def ring_perm(p: int) -> list[tuple[int, int]]:
    """ppermute permutation advancing the schedule: q's block goes to q-1.

    After the permute, processor q holds the block that was at q+1, i.e.
    block (q + 1 + r) mod p = sigma(q, r+1, p).  Entries are (src, dst).
    """
    return [(q, (q - 1) % p) for q in range(p)]


def partition_even(n: int, p: int) -> list[slice]:
    """p contiguous near-equal slices of range(n) (|I_q| ~ n/p, Thm 1 ass. 1)."""
    bounds = np.linspace(0, n, p + 1).astype(int)
    return [slice(int(bounds[k]), int(bounds[k + 1])) for k in range(p)]


def pad_to_multiple(n: int, p: int) -> int:
    return ((n + p - 1) // p) * p
