"""AdamW with decoupled weight decay + global-norm clipping (pure JAX)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    mu: object    # pytree like params (float32)
    nu: object
    step: jax.Array


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (not norms/biases)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_mu, new_nu, step), {
        "grad_norm": gnorm, "lr": lr}
